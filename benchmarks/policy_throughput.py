"""Policy-layer throughput + carbon head-to-head on the diurnal fleet stream.

Routes the same 1M-request diurnal trace (the `examples/serving_router.py`
stream) under every kind of ``RoutingPolicy`` — Table-1 oracle (carbon +
latency/energy baseline variants), fitted learned schedulers (regression /
classification inference in pure JAX), and both capacity formulations: the
PR-2 ``lax.scan`` CapacityLimiter and the segment-rank ``PlacementPolicy``
(identical decisions, pinned head-to-head for the >=5x speedup criterion) —
and reports each policy's req/s, total gCO2, carbon saved vs. the
latency-optimal baseline, and QoS/shed rates.

A second section routes the *multi-region* diurnal stream (staggered peak
hours, skewed load shares) through the placement layer: uncapped oracle vs.
tier-only spill vs. cross-region spill on a fully-connected ``CarbonGrid``,
pinning the gCO2 reduction from making region a placement axis.

Run:  PYTHONPATH=src python -m benchmarks.policy_throughput [--n 1000000]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import BenchRow
from repro.configs import get_config
from repro.core import CarbonGrid, build_scenarios, explore, paper_fleet
from repro.core.design_space import ScenarioAxes
from repro.core.schedulers import (
    ClassificationScheduler,
    RegressionScheduler,
    build_dataset,
)
from repro.core.workloads import ALL_PAPER_WORKLOADS
from repro.serve import (
    CapacityLimiter,
    FleetRouter,
    LearnedPolicy,
    OraclePolicy,
    PlacementPolicy,
)
from repro.serve.streams import diurnal_stream, multi_region_stream

ARCH = "h2o-danube-1.8b"


def fit_dataset():
    """Small offline design-space dataset for the learned policies."""
    axes = ScenarioAxes(hours=tuple(range(0, 24, 4)))
    table = build_scenarios(paper_fleet(), axes)
    res = explore(ALL_PAPER_WORKLOADS, table)
    return build_dataset(ALL_PAPER_WORKLOADS, res, table).split()[0]


def _time_stream(fr, batch, region, t_hours, reps):
    res = fr.route_stream(batch, region, t_hours)  # compile + warm
    jax.block_until_ready(res.target)
    t0 = time.perf_counter()
    for _ in range(reps):
        res = fr.route_stream(batch, region, t_hours)
    jax.block_until_ready(res.target)
    return (time.perf_counter() - t0) / reps, res


def run(n: int = 1_000_000, reps: int = 3) -> list[BenchRow]:
    cfg = get_config(ARCH)
    base = FleetRouter(cfg)
    infra = base.infra
    n_regions = len(base.regions)
    batch, region, t_hours = diurnal_stream(n, n_regions)

    train = fit_dataset()
    caps = np.full((n_regions, 3), np.inf)
    caps[:, 1] = max(1.0, 0.5 * n / (n_regions * 24))  # bind the edge tier

    policies = [
        ("oracle_carbon", None),  # FleetRouter default — the reference
        ("oracle_latency", OraclePolicy(infra, metric="latency")),
        ("oracle_energy", OraclePolicy(infra, metric="energy")),
        ("learned_regression", LearnedPolicy.fit(RegressionScheduler(),
                                                 train)),
        ("learned_classification", LearnedPolicy.fit(
            ClassificationScheduler(), train)),
        # the same caps through both capacity formulations: PR-2 lax.scan
        # reference vs. the segment-rank placement layer (identical
        # decisions; the speedup between these two rows is the ISSUE-3
        # >=5x acceptance criterion)
        ("capped_oracle_scan", CapacityLimiter(OraclePolicy(infra), caps)),
        ("capped_oracle_segrank", PlacementPolicy(OraclePolicy(infra),
                                                  caps)),
    ]

    rows = []
    baseline_g = None
    capped_us = {}
    for name, policy in policies:
        fr = base if policy is None else FleetRouter(cfg, policy=policy)
        dt, res = _time_stream(fr, batch, region, t_hours, reps)
        us = dt / n * 1e6
        if baseline_g is None:
            baseline_g = float(res.latency_opt_carbon_g)
        if name.startswith("capped_oracle"):
            capped_us[name] = us
        extra = ""
        if name == "capped_oracle_segrank":
            extra = (f" speedup_vs_scan="
                     f"{capped_us['capped_oracle_scan'] / us:.2f}x")
        rows.append(BenchRow(
            f"policy_{name}", us,
            f"req/s={1e6 / us:.0f} carbon_g={float(res.total_carbon_g):.4g} "
            f"saved_vs_latency_g={baseline_g - float(res.total_carbon_g):.4g} "
            f"qos_rate={float(res.qos_violation_rate):.4f} "
            f"shed={int(res.shed_count)}{extra}"))

    rows += placement_rows(cfg, infra, n=n, reps=reps)
    return rows


def placement_rows(cfg, infra, n: int, reps: int = 1) -> list[BenchRow]:
    """Multi-region skewed stream: uncapped vs tier-spill vs cross-region
    spill — the README results table."""
    base = FleetRouter(cfg)
    n_regions = len(base.regions)
    batch, region, t_hours = multi_region_stream(n, n_regions)
    caps = np.full((n_regions, 3), np.inf)
    per_cell = max(1.0, 0.4 * n / (n_regions * 24))
    caps[:, 1] = per_cell  # bind both DC tiers: the busy region overflows
    caps[:, 2] = per_cell  # (0.8x mean demand fleet-wide, uneven per region)
    xgrid = CarbonGrid.fully_connected(base.regions, latency_penalty=1.05)
    configs = [
        ("placement_uncapped", base),
        ("placement_tier_spill", FleetRouter(cfg, policy=PlacementPolicy(
            OraclePolicy(infra), caps))),
        ("placement_xregion_spill", FleetRouter(
            cfg, grid=xgrid,
            policy=PlacementPolicy(OraclePolicy(infra), caps))),
    ]
    rows = []
    for name, fr in configs:
        dt, res = _time_stream(fr, batch, region, t_hours, reps)
        us = dt / n * 1e6
        rows.append(BenchRow(
            name, us,
            f"req/s={1e6 / us:.0f} carbon_g={float(res.total_carbon_g):.4g} "
            f"routed_g={float(res.routed_carbon_g):.4g} "
            f"shed={int(res.shed_count)} "
            f"spilled={int(res.spilled_count)}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(args.n, args.reps):
        print(row.csv())


if __name__ == "__main__":
    main()
