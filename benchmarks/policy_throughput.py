"""Policy-layer throughput + carbon head-to-head on the diurnal fleet stream.

Routes the same 1M-request diurnal trace (the `examples/serving_router.py`
stream) under every kind of ``RoutingPolicy`` — Table-1 oracle (carbon +
latency/energy baseline variants), fitted learned schedulers (regression /
classification inference in pure JAX), and both capacity formulations: the
PR-2 ``lax.scan`` CapacityLimiter and the segment-rank ``PlacementPolicy``
(identical decisions, pinned head-to-head for the >=5x speedup criterion) —
and reports each policy's req/s, total gCO2, carbon saved vs. the
latency-optimal baseline, and QoS/shed rates.

A second section routes the *multi-region* diurnal stream (staggered peak
hours, skewed load shares) through the placement layer: uncapped oracle vs.
tier-only spill vs. cross-region spill on a fully-connected ``CarbonGrid``,
pinning the gCO2 reduction from making region a placement axis — and the
full PR-3 program (per-region Table-1 sweeps + fixed-round admission,
``factorized=False``) vs. the factorized einsum evaluator + skip-full
admission, head-to-head twice. The *uncapped* pair makes identical
decisions (admission never binds; this speedup is the ISSUE-4 >=3x
placement-path acceptance criterion); the *capped* pair additionally
swaps the admission algorithm, so decisions may differ where capacity
binds (near-identical aggregates in practice — see the shed/carbon
columns) and its speedup is the end-to-end program comparison.

A third section routes ``deferrable_stream`` (deadline-tagged batch-class
slice) through the temporal deferral engine: immediate (PR-3 cross-region
spill) vs. defer-only (identity adjacency) vs. joint spatio-temporal
placement, pinning the gCO2 reduction from making the HOUR a placement
axis. Runs at min(n, 200k): candidate scores are (N, S+1, R, 3).

A fourth section is the ISSUE-5 multi-day + learned-factorized pin. At
full n the cross-region placement path runs learned-vs-oracle head-to-head
on the factorized einsum engines (the ~2x-of-oracle learned-throughput
acceptance: a CI-linear classification scheduler collapses to one probed
einsum, the piecewise regression scheduler re-featurizes per candidate
region). At min(n, 200k) the joint deferral engines route the 2-day
``deferrable_stream_multiday`` against a matching 2-day rolling
``CarbonGrid`` (the horizon tail is non-wrapping — windows past the last
hour are refused, so no guard-day padding): oracle vs. learned joint
(region, tier, hour) scheduling, plus a repeated-diurnal vs. day-scaled
(cleaner day two, via ``scaled_days``) grid pair showing midnight-crossing
deferral chasing tomorrow's greener hours — capacity charged to day-two
cells, not aliased into day one's.

A fifth section is the ISSUE-6 forecast-native pin: the grid carries a
rolling CI forecast with realistic error (``sigma_h * sqrt(lead)``);
policies decide on the forecast, carbon is charged at the actuals.
Immediate cross-region routing vs. one-shot error-blind deferral vs. the
rolling risk-aware re-planner (``route_stream_rolling`` + the
``EmissionsLedger``). ASSERTS the forecast-aware re-planner routes less
gCO2 than immediate routing — `benchmarks.run` turns an assertion into a
failing CI job.

A sixth section is the ISSUE-7 continuous-batching queue pin. At full n
the raw serve loop (``repro.serve.queue.serve_stream``: EDF batch
formation, live ``WorkerPool`` slots through the cap_scale seam, per-step
commits) drains the diurnal stream — the >= 0.3M req/s acceptance. At
min(n, 30k) the online-refit gap trio routes the multiday joint-deferral
stream through the SAME queue loop: the static offline-fitted
classification policy vs. the ``OnlineRefitter`` hot-swap loop vs. the
oracle, reporting req/s + routed gCO2 + the fraction of the
static-vs-oracle gap the refit closes. ASSERTS refit routes no dirtier
than static — the `--smoke` CI gate.

A seventh section is the ISSUE-8 device-scaling pin. The capped
cross-region placement stream (the reconciliation-heavy admission mode)
runs through the ``shard_map`` sharded routing path on 1/2/4/.../D-device
meshes (``XLA_FLAGS=--xla_force_host_platform_device_count=N`` CPU fakes
in CI) against the single-device program. Decisions are bit-identical at
every device count — hard-asserted here: routed gCO2 through the sharded
path must be EXACT across counts and match the single-device program to
f32 round-off — and the per-count speedup is reported; the >=3x-at-8
acceptance asserts only where it can hold (the full 10M stream on >= 8
devices with >= 8 physical cores). ``enable_compile_cache`` is wired
first, so CI's cached cache directory turns every rerun into a warm
start.

An eighth section is the mesoscale provisioning pin. A 128-site K=8
sparse carbon grid (``CarbonGrid.from_sites``) routes the skewed
multi-region stream through the gathered O(N·K) candidate formulation:
(a) a dense 4-region grid round-tripped through
``with_sparse_neighbors()`` must route bit-identically (hard parity
gate, runs in ``--smoke``); (b) the gathered scorer vs. the dense
O(N·R) scorer head-to-head — the >=3x acceptance asserts at n >= 1M;
(c) ``repro.serve.provision`` sizes per-(site, tier, hour) fleets
against the stream's demand forecast, charging each server-hour its
amortized embodied + idle operational carbon: provisioned-vs-static-
overprovision-vs-oracle total-carbon rows, ASSERTING the provisioned
plan carries less total gCO2 at equal-or-lower shed rate (the
``--smoke`` CI gate), plus an end-to-end ``serve_stream(plan=...)``
row where the plan drives ``WorkerPool`` launch/drain through the
cap_scale seam; (d) a ``grid_event_stream`` site-outage row — the dead
site's DC load must spill strictly along its sparse neighbor list; and
(e) when >= 4 devices are visible (CI exports
``XLA_FLAGS=--xla_force_host_platform_device_count=4``) the 128-site
sparse stream re-routes through the ``shard_map`` path, bit-identical
to the single-device program.

Run:  PYTHONPATH=src python -m benchmarks.policy_throughput [--n 1000000]
      [--devices 8] [--profile-dir /tmp/trace]
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchRow
from repro.configs import get_config
from repro.core import (
    CarbonGrid,
    build_scenarios,
    carbon_model,
    explore,
    paper_fleet,
)
from repro.core.design_space import ScenarioAxes
from repro.core.schedulers import (
    ClassificationScheduler,
    RegressionScheduler,
    build_dataset,
)
from repro.core.workloads import ALL_PAPER_WORKLOADS
from repro.serve import (
    CapacityLimiter,
    EmissionsLedger,
    FleetRouter,
    LearnedPolicy,
    OnlineRefitter,
    OraclePolicy,
    PlacementPolicy,
    TemporalPolicy,
    WorkerPool,
    data_mesh,
    demand_from_arrivals,
    enable_compile_cache,
    oracle_plan,
    provision_greedy,
    serve_stream,
    static_overprovision_plan,
)
from repro.serve.streams import (
    deferrable_stream,
    deferrable_stream_multiday,
    diurnal_stream,
    forecast_scenario,
    grid_event_stream,
    multi_region_stream,
)

ARCH = "h2o-danube-1.8b"


def fit_dataset():
    """Small offline design-space dataset for the learned policies."""
    axes = ScenarioAxes(hours=tuple(range(0, 24, 4)))
    table = build_scenarios(paper_fleet(), axes)
    res = explore(ALL_PAPER_WORKLOADS, table)
    return build_dataset(ALL_PAPER_WORKLOADS, res, table).split()[0]


def _time_stream(fr, batch, region, t_hours, reps, mesh=None):
    """(mean_s, best_s, result) over ``reps`` timed calls after a warm-up.

    Best-of-reps is reported alongside the mean everywhere: on shared CI
    runners the mean soaks up scheduler noise while the best approximates
    the machine's actual capability — a regression that moves BOTH is
    real."""
    res = fr.route_stream(batch, region, t_hours, mesh=mesh)  # compile+warm
    jax.block_until_ready(res.target)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        res = fr.route_stream(batch, region, t_hours, mesh=mesh)
        jax.block_until_ready(res.target)
        times.append(time.perf_counter() - t0)
    return sum(times) / reps, min(times), res


def run(n: int = 1_000_000, reps: int = 3,
        devices: int | None = None) -> list[BenchRow]:
    cfg = get_config(ARCH)
    base = FleetRouter(cfg)
    infra = base.infra
    n_regions = len(base.regions)
    batch, region, t_hours = diurnal_stream(n, n_regions)

    train = fit_dataset()
    caps = np.full((n_regions, 3), np.inf)
    caps[:, 1] = max(1.0, 0.5 * n / (n_regions * 24))  # bind the edge tier

    policies = [
        ("oracle_carbon", None),  # FleetRouter default — the reference
        ("oracle_latency", OraclePolicy(infra, metric="latency")),
        ("oracle_energy", OraclePolicy(infra, metric="energy")),
        ("learned_regression", LearnedPolicy.fit(RegressionScheduler(),
                                                 train)),
        ("learned_classification", LearnedPolicy.fit(
            ClassificationScheduler(), train)),
        # the same caps through both capacity formulations: PR-2 lax.scan
        # reference vs. the segment-rank placement layer (identical
        # decisions; the speedup between these two rows is the ISSUE-3
        # >=5x acceptance criterion)
        ("capped_oracle_scan", CapacityLimiter(OraclePolicy(infra), caps)),
        ("capped_oracle_segrank", PlacementPolicy(OraclePolicy(infra),
                                                  caps)),
    ]

    rows = []
    baseline_g = None
    capped_us = {}
    for name, policy in policies:
        fr = base if policy is None else FleetRouter(cfg, policy=policy)
        dt, dt_best, res = _time_stream(fr, batch, region, t_hours, reps)
        us = dt / n * 1e6
        if baseline_g is None:
            baseline_g = float(res.latency_opt_carbon_g)
        if name.startswith("capped_oracle"):
            capped_us[name] = us
        extra = ""
        if name == "capped_oracle_segrank":
            extra = (f" speedup_vs_scan="
                     f"{capped_us['capped_oracle_scan'] / us:.2f}x")
        rows.append(BenchRow(
            f"policy_{name}", us,
            f"req/s={1e6 / us:.0f} best_req_s={n / dt_best:.0f} "
            f"carbon_g={float(res.total_carbon_g):.4g} "
            f"saved_vs_latency_g={baseline_g - float(res.total_carbon_g):.4g} "
            f"qos_rate={float(res.qos_violation_rate):.4f} "
            f"shed={int(res.shed_count)}{extra}"))

    rows += placement_rows(cfg, infra, n=n, reps=reps)
    rows += temporal_rows(cfg, infra, n=min(n, 200_000), reps=reps)
    rows += multiday_rows(cfg, infra, train, n=n, reps=reps)
    rows += forecast_rows(cfg, infra, n=min(n, 50_000), reps=reps)
    rows += queue_rows(cfg, infra, train, n=n, reps=reps)
    rows += device_rows(cfg, infra, n=n, reps=reps, devices=devices)
    rows += mesoscale_rows(cfg, infra, n=n, reps=reps)
    return rows


def device_rows(cfg, infra, n: int, reps: int = 1,
                devices: int | None = None) -> list[BenchRow]:
    """ISSUE-8 device-scaling pin: the capped cross-region placement
    stream (the reconciliation-heavy admission mode) through the
    ``shard_map`` sharded routing path on 1/2/4/.../D-device meshes vs the
    single-device program.

    Hard parity gates at EVERY count: decisions bit-identical, routed
    gCO2 EXACT across device counts (the sharded path aggregates
    host-side from bit-identical per-row arrays) and equal to the
    single-device program to f32 round-off. The >=3x-at-8-devices
    acceptance asserts only where it can hold: the full 10M-request
    stream on >= 8 devices backed by >= 8 physical cores (fake CPU
    devices share cores, so speedup on a small host measures nothing).
    """
    enable_compile_cache()
    avail = len(jax.devices())
    want = avail if devices is None else devices
    if want > avail:
        return [BenchRow(
            "devices_unavailable", 0.0,
            f"requested {want} devices but only {avail} present — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={want}")]
    d_list = [d for d in (1, 2, 4, 8, 16, 32, 64) if d <= want]

    base = FleetRouter(cfg)
    n_regions = len(base.regions)
    batch, region, t_hours = multi_region_stream(n, n_regions)
    caps = np.full((n_regions, 3), np.inf)
    per_cell = max(1.0, 0.4 * n / (n_regions * 24))
    caps[:, 1] = caps[:, 2] = per_cell  # binding: reconciliation is live
    xgrid = CarbonGrid.fully_connected(base.regions, latency_penalty=1.05)
    fr = FleetRouter(cfg, grid=xgrid,
                     policy=PlacementPolicy(OraclePolicy(infra), caps))

    dt, dt_best, ref = _time_stream(fr, batch, region, t_hours, reps)
    # snapshot NOW: with the persistent cache warm the donated-buffer
    # programs recycle retained results' memory on the next route call, so
    # a lazy np.asarray view read after later calls sees scribbled data
    ref_tgt = np.array(ref.target)
    ref_routed = float(ref.routed_carbon_g)
    rows = [BenchRow(
        "devices_single_program", dt / n * 1e6,
        f"req/s={n / dt:.0f} best_req_s={n / dt_best:.0f} "
        f"routed_g={ref_routed:.6g} "
        f"shed={int(ref.shed_count)}")]

    tgt1 = routed1 = us1 = None
    speedup = 1.0
    for d in d_list:
        mesh = data_mesh(d)
        dt, dt_best, res = _time_stream(fr, batch, region, t_hours, reps,
                                        mesh=mesh)
        us = dt / n * 1e6
        routed = float(res.routed_carbon_g)
        tgt = np.array(res.target)  # copy before the next route call
        if tgt1 is None:
            tgt1, routed1, us1 = tgt, routed, us
        # the headline invariant: sharding is not allowed to change a
        # single decision or move the routed total by one bit
        assert np.array_equal(tgt, tgt1), \
            f"sharded decisions diverged at {d} devices"
        assert routed == routed1, (
            f"sharded routed gCO2 not bit-stable across device counts: "
            f"{routed!r} at {d} devices vs {routed1!r} at {d_list[0]}")
        np.testing.assert_allclose(
            routed, ref_routed, rtol=1e-5,
            err_msg=f"sharded routed gCO2 != single-device at {d} devices")
        assert np.array_equal(tgt, ref_tgt), \
            f"sharded decisions != single-device program at {d} devices"
        speedup = us1 / us
        rows.append(BenchRow(
            f"devices_shard_{d}", us,
            f"req/s={n / dt:.0f} best_req_s={n / dt_best:.0f} "
            f"routed_g={routed:.6g} shed={int(res.shed_count)} "
            f"speedup_vs_1dev={speedup:.2f}x"))

    # the ISSUE-8 acceptance: >=3x at 8 devices on the full 10M stream —
    # gated on real parallel hardware (fake devices time-slicing one core
    # can only show parity, not speedup)
    if n >= 10_000_000 and max(d_list) >= 8 and (os.cpu_count() or 1) >= 8:
        assert speedup >= 3.0, (
            f"sharded routing at {max(d_list)} devices reached only "
            f"{speedup:.2f}x over 1 device (>=3x required at n={n})")
    return rows


def placement_rows(cfg, infra, n: int, reps: int = 1) -> list[BenchRow]:
    """Multi-region skewed stream: uncapped vs tier-spill vs cross-region
    spill (legacy sweep AND factorized einsum evaluator) — the README
    results table + the >=3x factorization speedup pin."""
    base = FleetRouter(cfg)
    n_regions = len(base.regions)
    batch, region, t_hours = multi_region_stream(n, n_regions)
    caps = np.full((n_regions, 3), np.inf)
    per_cell = max(1.0, 0.4 * n / (n_regions * 24))
    caps[:, 1] = per_cell  # bind both DC tiers: the busy region overflows
    caps[:, 2] = per_cell  # (0.8x mean demand fleet-wide, uneven per region)
    xgrid = CarbonGrid.fully_connected(base.regions, latency_penalty=1.05)
    free = np.full((n_regions, 3), np.inf)
    configs = [
        ("placement_uncapped", base),
        ("placement_tier_spill", FleetRouter(cfg, policy=PlacementPolicy(
            OraclePolicy(infra), caps))),
        # the PR-3 per-region Table-1 sweep program vs. the ISSUE-4
        # factorized einsum + skip-full admission, twice: under the PR-3
        # overload caps (carbon/shed continuity; admission contention
        # dominates), and uncapped — the pure placement-scoring path whose
        # speedup is the >=3x ISSUE-4 acceptance criterion
        ("placement_xregion_sweep", FleetRouter(
            cfg, grid=xgrid,
            policy=PlacementPolicy(OraclePolicy(infra), caps,
                                   factorized=False))),
        ("placement_xregion_einsum", FleetRouter(
            cfg, grid=xgrid,
            policy=PlacementPolicy(OraclePolicy(infra), caps))),
        ("placement_xregion_sweep_uncapped", FleetRouter(
            cfg, grid=xgrid,
            policy=PlacementPolicy(OraclePolicy(infra), free,
                                   factorized=False))),
        ("placement_xregion_einsum_uncapped", FleetRouter(
            cfg, grid=xgrid,
            policy=PlacementPolicy(OraclePolicy(infra), free))),
    ]
    rows = []
    sweep_us = {}
    for name, fr in configs:
        dt, dt_best, res = _time_stream(fr, batch, region, t_hours, reps)
        us = dt / n * 1e6
        if name.endswith("sweep") or name.endswith("sweep_uncapped"):
            sweep_us[name.replace("sweep", "einsum")] = us
        extra = ""
        if name in sweep_us:
            extra = f" speedup_vs_sweep={sweep_us[name] / us:.2f}x"
        rows.append(BenchRow(
            name, us,
            f"req/s={1e6 / us:.0f} best_req_s={n / dt_best:.0f} "
            f"carbon_g={float(res.total_carbon_g):.4g} "
            f"routed_g={float(res.routed_carbon_g):.4g} "
            f"shed={int(res.shed_count)} "
            f"spilled={int(res.spilled_count)}{extra}"))
    return rows


def temporal_rows(cfg, infra, n: int, reps: int = 1) -> list[BenchRow]:
    """Deadline-tagged stream: immediate (PR-3 cross-region spill) vs
    defer-only vs joint spatio-temporal deferral — the README temporal
    results table."""
    base = FleetRouter(cfg)
    n_regions = len(base.regions)
    batch, region, t_hours = deferrable_stream(n, n_regions)
    caps = np.full((n_regions, 3), np.inf)
    per_cell = max(1.0, 0.6 * n / (n_regions * 24))
    caps[:, 1] = per_cell  # moderate DC pressure: evening peaks overflow,
    caps[:, 2] = per_cell  # later windows have headroom
    xgrid = CarbonGrid.fully_connected(base.regions, latency_penalty=1.05)
    configs = [
        ("temporal_immediate", FleetRouter(
            cfg, grid=xgrid,
            policy=PlacementPolicy(OraclePolicy(infra), caps))),
        ("temporal_defer_only", FleetRouter(cfg, policy=TemporalPolicy(
            OraclePolicy(infra), caps, max_defer_h=12))),
        ("temporal_joint", FleetRouter(
            cfg, grid=xgrid,
            policy=TemporalPolicy(OraclePolicy(infra), caps,
                                  max_defer_h=12))),
    ]
    rows = []
    immediate_g = None
    for name, fr in configs:
        dt, dt_best, res = _time_stream(fr, batch, region, t_hours, reps)
        us = dt / n * 1e6
        if immediate_g is None:
            immediate_g = float(res.routed_carbon_g)
        rows.append(BenchRow(
            name, us,
            f"req/s={1e6 / us:.0f} best_req_s={n / dt_best:.0f} "
            f"routed_g={float(res.routed_carbon_g):.4g} "
            f"saved_vs_immediate_g="
            f"{immediate_g - float(res.routed_carbon_g):.4g} "
            f"shed={int(res.shed_count)} "
            f"spilled={int(res.spilled_count)} "
            f"deferred={int(res.deferred_count)} "
            f"mean_defer_h={float(res.mean_defer_hours):.2f}"))
    return rows


def multiday_rows(cfg, infra, train, n: int, reps: int = 1
                  ) -> list[BenchRow]:
    """Rolling multi-day horizon + learned policies on factorized engines.

    Full-n placement half: learned-vs-oracle cross-region einsum scoring
    (uncapped, multi-day stream/grid) — the learned-throughput-within-~2x
    pin. Reduced-n temporal half: learned-vs-oracle joint deferral under
    binding caps, and the repeated-diurnal vs cleaner-day-two grids.
    """
    base = FleetRouter(cfg)
    n_regions = len(base.regions)
    n_t = min(n, 200_000)
    batch, region, t_hours = deferrable_stream_multiday(n, n_regions,
                                                        n_days=2)
    # a 2-day grid matches the 2-day stream: the horizon tail is
    # non-wrapping, so the last arrivals' 16h windows past hour 47 are
    # refused rather than aliased — no guard-day padding
    grid2 = CarbonGrid.fully_connected(base.regions, latency_penalty=1.05,
                                       n_days=2)
    # day two 15% cleaner: the multi-day CI trajectory midnight-crossing
    # deferral should chase
    grid2c = grid2.scaled_days((1.0, 0.85))
    learned_lin = LearnedPolicy.fit(ClassificationScheduler(), train)
    learned_gen = LearnedPolicy.fit(RegressionScheduler(), train)
    free = np.full((n_regions, 3), np.inf)
    caps = np.full((n_regions, 3), np.inf)
    per_cell = max(1.0, 0.6 * n_t / (n_regions * 48))
    caps[:, 1] = caps[:, 2] = per_cell

    rows = []
    # --- full-n: learned vs oracle on the cross-region einsum path -------
    place = [
        ("multiday_place_oracle", OraclePolicy(infra)),
        ("multiday_place_learned_classification", learned_lin),
        ("multiday_place_learned_regression", learned_gen),
    ]
    oracle_us = None
    for name, inner in place:
        fr = FleetRouter(cfg, grid=grid2,
                         policy=PlacementPolicy(inner, free))
        dt, dt_best, res = _time_stream(fr, batch, region, t_hours, reps)
        us = dt / n * 1e6
        if oracle_us is None:
            oracle_us = us
        rows.append(BenchRow(
            name, us,
            f"req/s={1e6 / us:.0f} best_req_s={n / dt_best:.0f} "
            f"routed_g={float(res.routed_carbon_g):.4g} "
            f"spilled={int(res.spilled_count)} "
            f"vs_oracle={us / oracle_us:.2f}x"))

    # --- reduced-n: joint deferral across midnight, learned vs oracle ----
    bt, rt_, tt = (batch, region, t_hours) if n == n_t else \
        deferrable_stream_multiday(n_t, n_regions, n_days=2)
    temporal = [
        ("multiday_joint_oracle", grid2, OraclePolicy(infra)),
        ("multiday_joint_learned_classification", grid2, learned_lin),
        ("multiday_joint_learned_regression", grid2, learned_gen),
        ("multiday_joint_oracle_cleaner_day2", grid2c, OraclePolicy(infra)),
    ]
    oracle_us = oracle_g = None
    for name, grid, inner in temporal:
        fr = FleetRouter(cfg, grid=grid,
                         policy=TemporalPolicy(inner, caps, max_defer_h=16))
        dt, dt_best, res = _time_stream(fr, bt, rt_, tt, reps)
        us = dt / n_t * 1e6
        if oracle_us is None:
            oracle_us, oracle_g = us, float(res.routed_carbon_g)
        rows.append(BenchRow(
            name, us,
            f"req/s={1e6 / us:.0f} best_req_s={n_t / dt_best:.0f} "
            f"routed_g={float(res.routed_carbon_g):.4g} "
            f"saved_vs_oracle_g={oracle_g - float(res.routed_carbon_g):.4g} "
            f"shed={int(res.shed_count)} "
            f"deferred={int(res.deferred_count)} "
            f"mean_defer_h={float(res.mean_defer_hours):.2f} "
            f"vs_oracle={us / oracle_us:.2f}x"))
    return rows


def forecast_rows(cfg, infra, n: int, reps: int = 1) -> list[BenchRow]:
    """Forecast-native scheduling under realistic forecast error: immediate
    cross-region routing vs. one-shot error-blind deferral vs. the rolling
    risk-aware re-planner, all charged at ACTUAL CI. Asserts the
    forecast-aware re-planner beats immediate routing — run via
    ``benchmarks.run`` (and its ``--smoke`` CI job) this is a hard gate."""
    base = FleetRouter(cfg)
    batch, region, t_hours, grid = forecast_scenario(
        n, base.regions, sigma_h=0.03, seed=0)
    n_regions = len(base.regions)
    free = np.full((n_regions, 3), np.inf)
    immediate = FleetRouter(cfg, grid=grid, policy=PlacementPolicy(
        OraclePolicy(infra), free))
    blind = FleetRouter(cfg, grid=grid, policy=TemporalPolicy(
        OraclePolicy(infra), free, max_defer_h=12))
    aware = FleetRouter(cfg, grid=grid, policy=TemporalPolicy(
        OraclePolicy(infra), free, max_defer_h=12, risk_lambda=1.0))

    rows = []
    dt, dt_best, res_im = _time_stream(immediate, batch, region, t_hours,
                                       reps)
    g_im = float(res_im.routed_carbon_g)
    rows.append(BenchRow(
        "forecast_immediate", dt / n * 1e6,
        f"req/s={n / dt:.0f} best_req_s={n / dt_best:.0f} "
        f"routed_g={g_im:.4g} sigma_h=0.03"))

    dt, dt_best, res_bl = _time_stream(blind, batch, region, t_hours, reps)
    g_bl = float(res_bl.routed_carbon_g)
    rows.append(BenchRow(
        "forecast_oneshot_blind", dt / n * 1e6,
        f"req/s={n / dt:.0f} best_req_s={n / dt_best:.0f} "
        f"routed_g={g_bl:.4g} "
        f"saved_vs_immediate_g={g_im - g_bl:.4g} "
        f"deferred={int(res_bl.deferred_count)}"))

    roll = aware.route_stream_rolling(batch, region, t_hours, step_h=6,
                                      ledger=EmissionsLedger())  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        roll = aware.route_stream_rolling(batch, region, t_hours, step_h=6,
                                          ledger=EmissionsLedger())
    dt = (time.perf_counter() - t0) / reps
    g_rl = roll.routed_carbon_g
    rows.append(BenchRow(
        "forecast_rolling_risk_aware", dt / n * 1e6,
        f"req/s={n / dt:.0f} routed_g={g_rl:.4g} "
        f"saved_vs_immediate_g={g_im - g_rl:.4g} "
        f"saved_vs_oneshot_g={g_bl - g_rl:.4g} "
        f"deferred={roll.deferred_count} steps={len(roll.steps)}"))

    # the ISSUE-6 CI gate: forecast-aware deferral must beat routing
    # everything immediately on the realistic-error stream
    assert g_rl < g_im, (
        f"forecast-aware rolling deferral ({g_rl:.4g} g) failed to beat "
        f"immediate routing ({g_im:.4g} g) at sigma_h=0.03")
    return rows


def queue_rows(cfg, infra, train, n: int, reps: int = 1) -> list[BenchRow]:
    """ISSUE-7 continuous-batching queue: serve-loop throughput at full n
    (the >= 0.3M req/s acceptance) + the online-refit gap trio on the
    multiday joint-deferral stream at min(n, 30k). ASSERTS refit routes no
    dirtier than the static offline-fitted classification policy through
    the same queue loop — ``benchmarks.run --smoke`` turns the assertion
    into a failing CI job."""
    base = FleetRouter(cfg)
    n_regions = len(base.regions)

    # --- full-n: raw serve-loop throughput through live worker slots -----
    batch, region, t_hours = diurnal_stream(n, n_regions)
    xgrid = CarbonGrid.fully_connected(base.regions, latency_penalty=1.05)
    unit = np.ones((n_regions, 3))  # pool slots ARE the caps (cap_scale)
    fr = FleetRouter(cfg, grid=xgrid,
                     policy=PlacementPolicy(OraclePolicy(infra), unit))

    def mk_pool():
        pool = WorkerPool(n_regions, slots_per_worker=30_000.0,
                          launch_delay_steps=0)
        for r in range(n_regions):
            for tier in (1, 2):
                pool.launch(r, tier, n=2)
        return pool

    res = serve_stream(fr, batch, region, t_hours, pool=mk_pool())  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        res = serve_stream(fr, batch, region, t_hours, pool=mk_pool())
    dt = (time.perf_counter() - t0) / reps
    rows = [BenchRow(
        "queue_throughput", dt / n * 1e6,
        f"req/s={n / dt:.0f} routed_g={float(res.routed_carbon_g):.4g} "
        f"shed={res.shed_count} steps={len(res.steps)} "
        f"batches={sum(s.n_batches for s in res.steps)}")]

    # --- reduced-n: static-learned vs online-refit vs oracle -------------
    n_q = min(n, 30_000)
    bq, rq, tq = deferrable_stream_multiday(n_q, n_regions, n_days=2)
    grid2 = CarbonGrid.fully_connected(base.regions, latency_penalty=1.05,
                                       n_days=2)
    caps = np.full((n_regions, 3), np.inf)
    caps[:, 1] = caps[:, 2] = max(1.0, 0.6 * n_q / (n_regions * 48))
    static = LearnedPolicy.fit(ClassificationScheduler(carbon_head=False),
                               train, infra=infra)

    def q_serve(inner, refitter=None):
        frq = FleetRouter(cfg, grid=grid2, policy=TemporalPolicy(
            inner, caps, max_defer_h=16))
        t0 = time.perf_counter()
        resq = serve_stream(frq, bq, rq, tq, step_h=2, refitter=refitter)
        return time.perf_counter() - t0, resq

    mk_refitter = lambda: OnlineRefitter(
        min_observations=max(256, n_q // 12),
        refit_every=max(512, n_q // 6))
    configs = [
        ("queue_static_learned", lambda: q_serve(static)),
        ("queue_online_refit", lambda: q_serve(static, mk_refitter())),
        ("queue_oracle", lambda: q_serve(OraclePolicy(infra))),
    ]
    g = {}
    for name, fn in configs:
        fn()  # compile + warm (fresh refitter per run: cold replay state)
        dt, resq = fn()
        g[name] = float(resq.routed_carbon_g)
        extra = ""
        if name == "queue_online_refit":
            extra = f" refits={resq.refits}"
        elif name == "queue_oracle":
            gap = g["queue_static_learned"] - g[name]
            closed = (g["queue_static_learned"]
                      - g["queue_online_refit"]) / max(gap, 1e-9)
            extra = f" refit_gap_closed={closed:.1%}"
        rows.append(BenchRow(
            name, dt / n_q * 1e6,
            f"req/s={n_q / dt:.0f} routed_g={g[name]:.4g} "
            f"shed={resq.shed_count}{extra}"))

    # the ISSUE-7 CI gate: learning from the live stream must not route
    # dirtier than the static offline fit it started from
    assert g["queue_online_refit"] <= g["queue_static_learned"] * 1.001, (
        f"online refit ({g['queue_online_refit']:.4g} g) routed dirtier "
        f"than the static learned policy "
        f"({g['queue_static_learned']:.4g} g)")
    return rows


def mesoscale_rows(cfg, infra, n: int, reps: int = 1) -> list[BenchRow]:
    """Mesoscale provisioning pin: sparse-vs-dense parity, the O(N·K)
    scorer speedup, provision-vs-static-vs-oracle total carbon, the
    site-outage spill, and the sharded 128-site path. The parity and
    provisioning asserts run at every n — ``benchmarks.run --smoke``
    turns them into failing CI jobs; the >=3x scorer acceptance asserts
    at n >= 1M."""
    base = FleetRouter(cfg)

    # --- (a) dense round-trip parity: bit-identical routing ---------------
    n_p = min(n, 5_000)
    n_regions = len(base.regions)
    caps = np.full((n_regions, 3), np.inf)
    caps[:, 1] = caps[:, 2] = max(1.0, 0.4 * n_p / (n_regions * 24))
    dense_g = CarbonGrid.fully_connected(base.regions, latency_penalty=1.05)
    sparse_g = dense_g.with_sparse_neighbors()
    bp, rp, tp = deferrable_stream(n_p, n_regions, seed=0)
    rows = []
    for label, pol_cls in (("placement", PlacementPolicy),
                           ("temporal", TemporalPolicy)):
        fr_d = FleetRouter(cfg, grid=dense_g,
                           policy=pol_cls(OraclePolicy(infra), caps))
        fr_s = FleetRouter(cfg, grid=sparse_g,
                           policy=pol_cls(OraclePolicy(infra), caps))
        _, dt_d, rd = _time_stream(fr_d, bp, rp, tp, reps)
        # copy before the sparse router runs: the donated-buffer programs
        # may recycle this result's memory on the next route call
        tgt_d, g_d = np.array(rd.target), float(rd.total_carbon_g)
        _, dt_s, rs = _time_stream(fr_s, bp, rp, tp, reps)
        assert np.array_equal(tgt_d, np.asarray(rs.target)), \
            f"sparse round-trip diverged from the dense {label} program"
        assert g_d == float(rs.total_carbon_g), (
            f"sparse round-trip moved {label} total gCO2: "
            f"{float(rs.total_carbon_g)!r} vs {g_d!r}")
        rows.append(BenchRow(
            f"mesoscale_parity_{label}", dt_s / n_p * 1e6,
            f"req/s={n_p / dt_s:.0f} dense_req_s={n_p / dt_d:.0f} "
            f"carbon_g={float(rs.total_carbon_g):.4g} bit_identical=True"))

    # --- (b) gathered O(N·K) vs dense O(N·R) scorer at R=128, K=8 ---------
    r, k = 128, 8
    gs = CarbonGrid.from_sites(r, k, seed=0)
    gd = dataclasses.replace(gs, nbr_idx=None, nbr_rtt_s=None)
    free128 = jnp.asarray(np.full((r, 3), np.inf))
    pol_s = PlacementPolicy(OraclePolicy(infra), free128)
    pol_s.bind_grid(gs)
    pol_d = PlacementPolicy(OraclePolicy(infra), free128)
    pol_d.bind_grid(gd)
    batch, region, t_hours = multi_region_stream(n, r, seed=1)
    fr128 = FleetRouter(cfg, grid=gd)
    w = batch.workload(cfg)
    home = jnp.asarray(region)
    hr = jnp.asarray(np.floor(t_hours).astype(np.int32) % 24)
    env0 = fr128.env_at(0, 0)
    ci = jnp.asarray(gs.table)[home, hr]
    avail = jnp.asarray(np.asarray(batch.available))
    factors = carbon_model.energy_factors_batch(
        w, infra, env0.interference, env0.net_slowdown)

    @jax.jit
    def dense_scores(factors, w, avail, home, hr, ci):
        env = dataclasses.replace(env0, ci=ci)
        return pol_d.pair_scores_from_factors(factors, w, env, avail,
                                              home, hr)

    @jax.jit
    def sparse_scores(factors, w, avail, home, hr, ci):
        env = dataclasses.replace(env0, ci=ci)
        return pol_s.sparse_pair_scores_from_factors(
            factors, w, env, avail, home, hr)

    def best_of(f):
        jax.block_until_ready(f(factors, w, avail, home, hr, ci))  # warm
        t = np.inf
        for _ in range(max(reps, 2)):
            t0 = time.perf_counter()
            jax.block_until_ready(f(factors, w, avail, home, hr, ci))
            t = min(t, time.perf_counter() - t0)
        return t

    td, ts = best_of(dense_scores), best_of(sparse_scores)
    speedup = td / ts
    rows.append(BenchRow(
        "mesoscale_scorer_sparse", ts / n * 1e6,
        f"req/s={n / ts:.0f} dense_req_s={n / td:.0f} R={r} K={k} "
        f"speedup_vs_dense={speedup:.2f}x"))
    # the ISSUE-9 acceptance: O(N·K) >= 3x over O(N·R) on the 1M batch —
    # tiny batches are dispatch-bound, so the gate binds only at full n
    if n >= 1_000_000:
        assert speedup >= 3.0, (
            f"gathered scorer reached only {speedup:.2f}x over the dense "
            f"scorer at R={r}, K={k}, n={n} (>=3x required)")

    # --- (c) joint capacity provisioning on the 128-site grid -------------
    n_v = min(n, 20_000)
    bv, rv, tv = (batch, region, t_hours) if n == n_v else \
        multi_region_stream(n_v, r, seed=1)
    fleet = paper_fleet()
    demand = demand_from_arrivals(rv, tv, 24, r)
    prov = provision_greedy(demand, gs, fleet)
    slo = provision_greedy(demand, gs, fleet, slo_shed=0.02,
                           name="slo_0.02")
    stat = static_overprovision_plan(demand, gs, fleet)
    orac = oracle_plan(demand, gs, fleet)
    for plan in (prov, slo, stat, orac):
        rows.append(BenchRow(
            f"mesoscale_plan_{plan.name}", 0.0,
            f"server_h={plan.server_hours} "
            f"total_g={plan.total_carbon_g:.6g} "
            f"operational_g={plan.operational_g:.4g} "
            f"embodied_g={plan.embodied_g:.4g} "
            f"forecast_shed={plan.shed_rate:.4f}"))
    # the ISSUE-9 CI gate: demand-shaped provisioning must beat static
    # over-provisioning on total (operational + amortized embodied) carbon
    # at equal-or-lower shed rate
    assert prov.total_carbon_g < stat.total_carbon_g, (
        f"provisioned plan ({prov.total_carbon_g:.6g} g) failed to beat "
        f"static over-provisioning ({stat.total_carbon_g:.6g} g)")
    assert prov.shed_rate <= stat.shed_rate + 1e-12, (
        f"provisioned shed {prov.shed_rate:.4f} exceeds static "
        f"{stat.shed_rate:.4f}")
    assert slo.total_carbon_g <= orac.total_carbon_g

    # end-to-end: the plan drives WorkerPool launch/drain inside the serve
    # loop; admission sees provisioned slots through the cap_scale seam
    unit = np.ones((r, 3))
    fr_serve = FleetRouter(cfg, grid=gs, policy=PlacementPolicy(
        OraclePolicy(infra), jnp.asarray(unit)))
    t0 = time.perf_counter()
    res = serve_stream(fr_serve, bv, rv, tv, plan=prov)
    dt = time.perf_counter() - t0
    rows.append(BenchRow(
        "mesoscale_serve_provisioned", dt / n_v * 1e6,
        f"req/s={n_v / dt:.0f} routed_g={float(res.routed_carbon_g):.4g} "
        f"standing_g={prov.total_carbon_g:.6g} shed={res.shed_count} "
        f"steps={len(res.steps)}"))

    # --- (d) site outage: dead site's DC load spills along neighbors ------
    bo, ro, to, g_ev, outage = grid_event_stream(
        n_v, gs, seed=3, outage_site=5, outage_window=(0, 24))
    fr_ev = FleetRouter(cfg, grid=g_ev, policy=PlacementPolicy(
        OraclePolicy(infra), jnp.asarray(np.full((r, 3), np.inf))))
    scale = np.ones((r, 3), np.float32)
    scale[5, 1:] = 0.0  # the outage mask, capacity-side
    hour_np = (np.floor(to) % fr_ev._horizon_h).astype(np.int32)
    res_ev, _ = fr_ev._route_arrays(bo, np.asarray(ro, np.int32), hour_np,
                                    cap_scale=jnp.asarray(scale))
    exec_r = np.asarray(res_ev.exec_region)
    tgt = np.asarray(res_ev.target)
    on_dead = ((exec_r == 5) & (tgt > 0)).sum()
    assert on_dead == 0, \
        f"{on_dead} requests executed on the outaged site's DC tiers"
    spilled = int(((np.asarray(ro) == 5) & (exec_r != 5) & (tgt > 0)).sum())
    rows.append(BenchRow(
        "mesoscale_outage_spill", 0.0,
        f"outage_hours={int(np.asarray(outage).sum(axis=1).max())} "
        f"spilled_from_site5={spilled} "
        f"routed_g={float(res_ev.routed_carbon_g):.4g} "
        f"shed={int(res_ev.shed_count)}"))

    # --- (e) the 128-site sparse stream through the sharded path ----------
    if len(jax.devices()) >= 4:
        enable_compile_cache()
        caps128 = np.full((r, 3), np.inf)
        caps128[:, 1] = caps128[:, 2] = max(1.0, 0.4 * n_v / (r * 24))
        fr_sh = FleetRouter(cfg, grid=gs, policy=PlacementPolicy(
            OraclePolicy(infra), caps128))
        _, dt1, ref = _time_stream(fr_sh, bv, rv, tv, reps)
        ref_tgt = np.array(ref.target)  # copy before the sharded call
        _, dt4, shd = _time_stream(fr_sh, bv, rv, tv, reps,
                                   mesh=data_mesh(4))
        assert np.array_equal(np.asarray(shd.target), ref_tgt), \
            "sharded 128-site sparse routing diverged from single-device"
        rows.append(BenchRow(
            "mesoscale_shard_4dev", dt4 / n_v * 1e6,
            f"req/s={n_v / dt4:.0f} single_req_s={n_v / dt1:.0f} "
            f"routed_g={float(shd.routed_carbon_g):.6g} "
            f"shed={int(shd.shed_count)} bit_identical=True"))
    else:
        rows.append(BenchRow(
            "mesoscale_shard_unavailable", 0.0,
            f"needs >= 4 devices, {len(jax.devices())} present — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=4"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--devices", type=int, default=None,
                    help="device-scaling section mesh size (default: all "
                         "local devices; use XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N for "
                         "fake CPU devices)")
    ap.add_argument("--profile-dir", default=None,
                    help="write a jax.profiler trace of the whole run "
                         "here (view with TensorBoard / Perfetto)")
    args = ap.parse_args()
    if args.profile_dir:
        with jax.profiler.trace(args.profile_dir):
            rows = run(args.n, args.reps, devices=args.devices)
    else:
        rows = run(args.n, args.reps, devices=args.devices)
    print("name,us_per_call,derived")
    for row in rows:
        print(row.csv())


if __name__ == "__main__":
    main()
