"""Shared benchmark plumbing: reference environments, timing, row format."""

from __future__ import annotations

import dataclasses
import time
from functools import lru_cache

import jax
import numpy as np

from repro.core import (
    ChargingBehavior,
    Environment,
    Grid,
    grid_trace,
    mobile_carbon_intensity,
    pack_infra,
    paper_fleet,
)
from repro.core.design_space import CARBON_FREE_CI, RURAL_EXTRA_EDGE_LATENCY_S
from repro.core.runtime_variance import VarianceScenario, scenario_multipliers

TARGET_NAMES = ("Mobile", "EdgeDC", "DC")


@dataclasses.dataclass
class BenchRow:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived}"


def time_us(fn, *args, reps: int = 20) -> float:
    fn(*args)  # compile / warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


@lru_cache(maxsize=None)
def traces():
    return {g: grid_trace(g) for g in Grid}


@lru_cache(maxsize=None)
def ci_values():
    t = traces()
    core = float(np.mean([np.asarray(x.ci_hourly).mean()
                          for x in t.values()]))
    return {
        "night": float(mobile_carbon_intensity(ChargingBehavior.NIGHTTIME,
                                                t[Grid.CISO])),
        "avg": float(mobile_carbon_intensity(ChargingBehavior.AVERAGE,
                                             t[Grid.CISO])),
        "intel": float(mobile_carbon_intensity(ChargingBehavior.INTELLIGENT,
                                               t[Grid.CISO])),
        "urban": float(t[Grid.URBAN].ci_hourly.mean()),
        "rural": float(t[Grid.RURAL].ci_hourly.mean()),
        "ciso": float(t[Grid.CISO].ci_hourly.mean()),
        "core": core,
        "carbon_free": CARBON_FREE_CI,
    }


def reference_env(var: VarianceScenario = VarianceScenario.NONE, *,
                  mobile: str = "night", edge: str = "urban",
                  hyper: str = "ciso") -> Environment:
    """The paper's default scenario: Nighttime charger / Urban edge /
    Grid-Mix DC (used by Figs 5, 10-13)."""
    ci = ci_values()
    interf, net = scenario_multipliers(var)
    return Environment.make(ci[mobile], ci[edge], ci["core"], ci[hyper],
                            interference=interf, net_slowdown=net)


@lru_cache(maxsize=None)
def infra(embodied: str = "act", rural_edge: bool = False,
          device: str = "phone"):
    import jax.numpy as jnp
    base = pack_infra(paper_fleet(), embodied, device=device)
    if rural_edge:
        base = base.replace(net_lat=base.net_lat + jnp.asarray(
            [RURAL_EXTRA_EDGE_LATENCY_S, 0.0], jnp.float32))
    return base
