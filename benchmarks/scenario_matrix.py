"""Scenario-matrix benchmark: every policy over every named scenario.

Emits the pinned policy-vs-scenario results matrix (one
``scenario[<scenario>/<policy>]`` row per cell, ``total gCO2`` and rates in
the derived column) plus three in-bench gate rows that ASSERT — CI greps
them, so a regression fails the smoke job, not just drifts a number:

  * ``scenario_gate_curtailment_chase`` — on the curtailment scenarios the
    deferring policy must beat immediate routing on total gCO2, and some
    deferred work must actually execute inside the near-zero-CI window in
    the curtailed region (the deferral is chasing the window, not winning
    by accident).
  * ``scenario_gate_spike_aware`` — a demand-forecast-aware provisioning
    plan (spike re-injected into the smoothed forecast) must shed less of
    a 10x flash crowd than the spike-blind greedy plan, and must be no
    dirtier than the blanket static over-provision baseline at equal
    realized shed.
  * ``scenario_gate_watt_caps`` — watt-shaped per-(window, region, tier)
    admission counts never exceed the ``TierEnvelope``-derived cap matrix,
    property-tested over several stream seeds and both capped policies.

Usage: ``PYTHONPATH=src python -m benchmarks.scenario_matrix`` (standalone)
or via ``python -m benchmarks.run [--smoke]``. The standalone entry also
writes ``scenario-matrix.csv`` next to the CWD for the CI artifact.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import BenchRow
from repro.serve.scenarios import (
    _cell,
    caps_violation,
    default_policies,
    default_scenarios,
    matrix_csv,
    route_scenario,
)

#: CI grep-gate row names (pinned — .github/workflows/ci.yml greps these).
GATE_ROWS = ("scenario_gate_curtailment_chase", "scenario_gate_spike_aware",
             "scenario_gate_watt_caps")


def matrix_rows(n: int) -> tuple[list[BenchRow], list]:
    """One timed row per (scenario, policy) cell; returns the cells too so
    the gates reuse them instead of re-routing."""
    rows, cells = [], []
    scenarios, policies = default_scenarios(), default_policies()
    for sname, scenario in scenarios.items():
        for pname, factory in policies.items():
            t0 = time.perf_counter()
            res, _, run = route_scenario(scenario, factory, n=n)
            dt_us = (time.perf_counter() - t0) * 1e6
            c = _cell(sname, pname, len(run.batch), res)
            cells.append(c)
            rows.append(BenchRow(
                f"scenario[{c.scenario}/{c.policy}]", dt_us,
                f"n={c.n} total_g={c.total_g:.3f} "
                f"routed_g={c.routed_g:.3f} shed={c.shed_rate:.3f} "
                f"spill={c.spill_rate:.3f} defer={c.defer_rate:.3f}"))
    return rows, cells


def curtailment_gate(cells: list, n: int) -> list[BenchRow]:
    """Deferral must CHASE the curtailment window: beat immediate routing
    on total gCO2 on both curtailment scenarios, with deferred work
    actually landing inside the window in the curtailed region."""
    by = {(c.scenario, c.policy): c for c in cells}
    t0 = time.perf_counter()
    for sname in ("curtailment_midday", "curtailment_zero_ci"):
        defer, imm = by[(sname, "temporal-defer")], by[(sname,
                                                       "oracle-immediate")]
        assert defer.total_g < imm.total_g, (
            f"{sname}: deferral ({defer.total_g:.3f} g) must beat "
            f"immediate routing ({imm.total_g:.3f} g)")
    scenario = default_scenarios()["curtailment_midday"]
    ev = scenario.event
    res, state, run = route_scenario(
        scenario, default_policies()["temporal-defer"], n=n)
    deferred = (np.asarray(state.defer_hours) > 0) & ~np.asarray(state.shed)
    exec_hod = np.asarray(state.exec_hour) % 24
    in_window = ((np.asarray(state.exec_region) == ev.curtail_region)
                 & (exec_hod >= ev.curtail_window[0])
                 & (exec_hod < ev.curtail_window[1]))
    landed = int((deferred & in_window).sum())
    assert landed > 0, "no deferred work landed in the curtailment window"
    dt_us = (time.perf_counter() - t0) * 1e6
    d = by[("curtailment_midday", "temporal-defer")]
    i = by[("curtailment_midday", "oracle-immediate")]
    return [BenchRow("scenario_gate_curtailment_chase", dt_us,
                     f"defer_g={d.total_g:.3f} immediate_g={i.total_g:.3f} "
                     f"landed_in_window={landed} PASS")]


def spike_aware_gate(n: int) -> list[BenchRow]:
    """Demand-forecast-aware provisioning must pre-stage the flash crowd:
    less realized shed than the spike-blind greedy plan, and no dirtier
    than blanket static over-provisioning at equal realized shed."""
    from repro.core.carbon_intensity import DEFAULT_REGIONS, CarbonGrid
    from repro.core.infrastructure import tpu_fleet
    from repro.serve.forecast import EmissionsLedger
    from repro.serve.provision import (
        demand_from_arrivals,
        provision_greedy,
        realized_shed_rate,
        smoothed_demand_forecast,
        spike_demand_forecast,
        static_overprovision_plan,
    )
    from repro.serve.streams import arrival_stream

    t0 = time.perf_counter()
    n_regions, spike_at, spike_mult, spike_w = 4, 20.0, 10.0, 2.0
    _, region, t_hours = arrival_stream(
        max(n, 1) / 24.0, 24.0, n_regions, 0, spike_at_h=spike_at,
        spike_mult=spike_mult, spike_width_h=spike_w)
    actual = demand_from_arrivals(region, t_hours, 24, n_regions)
    blind_fc = smoothed_demand_forecast(actual)
    aware_fc = spike_demand_forecast(actual, spike_at_h=spike_at,
                                     spike_mult=spike_mult,
                                     spike_width_h=spike_w)
    grid = CarbonGrid.fully_connected(DEFAULT_REGIONS)
    fleet = tpu_fleet()
    # fine-grained servers: at smoke-scale demand a 64-slot server would
    # mask the spike behind integer sizing granularity
    slots = 8.0
    aware = provision_greedy(aware_fc, grid, fleet, name="spike-aware",
                             slots_per_server=slots)
    blind = provision_greedy(blind_fc, grid, fleet, name="spike-blind",
                             slots_per_server=slots)
    static = static_overprovision_plan(blind_fc, grid, fleet,
                                      headroom=spike_mult,
                                      slots_per_server=slots)
    shed_aware = realized_shed_rate(aware, actual)
    shed_blind = realized_shed_rate(blind, actual)
    shed_static = realized_shed_rate(static, actual)
    assert shed_aware < shed_blind, (
        f"spike-aware plan must shed less of the crowd than the blind "
        f"plan ({shed_aware:.4f} vs {shed_blind:.4f})")
    # ~equal shed: static's blanket 10x headroom also absorbs off-spike
    # Poisson noise the aware plan does not forecast, so allow 1 pp
    assert shed_aware <= shed_static + 0.01, (
        f"equal-shed comparison broken: aware {shed_aware:.4f} vs "
        f"static {shed_static:.4f}")
    assert aware.total_carbon_g <= static.total_carbon_g, (
        f"spike-aware plan ({aware.total_carbon_g:.1f} g) must be no "
        f"dirtier than static over-provisioning "
        f"({static.total_carbon_g:.1f} g) at equal realized shed")
    # the ledger side of the same signal: with a demand forecast attached,
    # capacity is conserved in the step BEFORE the predicted spike
    d_hourly = actual.sum(axis=(1, 2))
    led = EmissionsLedger(demand_fc=d_hourly)
    flat_ci = np.full((n_regions, 24), 100.0)
    scale_pre, _, _, _ = led.cap_scales(flat_ci, 12, 6, np.zeros(n_regions))
    assert float(scale_pre.max()) < 1.0, (
        "ledger must conserve capacity ahead of the predicted spike")
    dt_us = (time.perf_counter() - t0) * 1e6
    return [BenchRow(
        "scenario_gate_spike_aware", dt_us,
        f"aware_shed={shed_aware:.4f} blind_shed={shed_blind:.4f} "
        f"aware_g={aware.total_carbon_g:.1f} "
        f"static_g={static.total_carbon_g:.1f} "
        f"ledger_prestage_scale={float(scale_pre.max()):.2f} PASS")]


def watt_caps_gate(n: int, seeds=(0, 1, 2)) -> list[BenchRow]:
    """Property test: per-(window, region, tier) admission counts of the
    watt-shaped fleet never exceed the TierEnvelope-derived cap matrix —
    over several stream seeds and both capped policies."""
    t0 = time.perf_counter()
    base = default_scenarios()["hetero_fleet_watt"]
    policies = default_policies()
    worst = -np.inf
    for seed in seeds:
        scenario = dataclasses.replace(base, seed=seed)
        for pname in ("oracle-immediate", "temporal-defer"):
            res, state, run = route_scenario(scenario, policies[pname], n=n)
            v = caps_violation(res, state, run.t_hours, run.caps,
                               run.grid.table.shape[1])
            worst = max(worst, v)
            assert v <= 0.0, (
                f"watt caps exceeded by {v} (seed={seed}, policy={pname})")
    dt_us = (time.perf_counter() - t0) * 1e6
    return [BenchRow("scenario_gate_watt_caps", dt_us,
                     f"seeds={len(seeds)} worst_excess={worst:.0f} PASS")]


def run(n: int = 2000, *, csv_path: str | None = None) -> list[BenchRow]:
    """The full section list; ``csv_path`` additionally writes the raw
    matrix as CSV (the CI artifact)."""
    rows, cells = matrix_rows(n)
    rows += curtailment_gate(cells, n)
    rows += spike_aware_gate(n)
    rows += watt_caps_gate(min(n, 600))
    if csv_path is not None:
        with open(csv_path, "w") as f:
            f.write(matrix_csv(cells) + "\n")
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run(csv_path="scenario-matrix.csv"):
        print(row.csv())
