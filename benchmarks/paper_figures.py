"""Benchmarks reproducing every GreenScale table/figure (Figs 5-14).

Each ``fig*`` function returns BenchRow(s): the timed core computation plus
the derived quantity the paper's figure reports. ``benchmarks.run`` prints
them as CSV and EXPERIMENTS.md §Paper-validation records the comparison
against the paper's claims.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    BenchRow,
    TARGET_NAMES,
    ci_values,
    infra,
    reference_env,
    time_us,
)
from repro.core import carbon_model
from repro.core.carbon_model import Environment, evaluate, evaluate_energy
from repro.core.runtime_variance import VarianceScenario
from repro.core.workloads import (
    ALL_PAPER_WORKLOADS,
    ARVR_WORKLOADS,
    by_name,
)


def _solve(w, inf, env, avail=(True, True, True)):
    b = evaluate(w, inf, env)
    ok = carbon_model.feasible(b, w)
    av = jnp.asarray(avail)
    energy = evaluate_energy(w, inf, env)
    return {
        "copt": int(carbon_model.pick_target(b.total_cf, ok, b.total_cf, av)),
        "eopt": int(carbon_model.pick_target(energy, ok, b.total_cf, av)),
        "lopt": int(carbon_model.pick_target(b.latency, ok, b.total_cf, av)),
        "cf": np.asarray(b.total_cf), "energy": np.asarray(energy),
        "lat": np.asarray(b.latency), "ok": np.asarray(ok & av),
        "op": np.asarray(b.op_cf), "emb": np.asarray(b.emb_cf),
    }


def fig5_design_space() -> list[BenchRow]:
    """Per-workload perf/energy/carbon-optimal execution targets."""
    inf = infra("act")
    env = reference_env()
    t = time_us(lambda: evaluate(by_name("resnet50").workload, inf, env))
    rows = []
    for info in ALL_PAPER_WORKLOADS:
        dev_inf = infra("act", device=info.device)
        s = _solve(info.workload, dev_inf, env, info.available_targets)
        rows.append(BenchRow(
            f"fig5/{info.name}", t,
            f"carbon={TARGET_NAMES[s['copt']]};energy="
            f"{TARGET_NAMES[s['eopt']]};latency={TARGET_NAMES[s['lopt']]}"))
    return rows


def fig6_scheduler_gap() -> list[BenchRow]:
    """Carbon-aware vs energy-aware scheduling across the design space.
    Paper claim: up to 29.1% CF reduction."""
    from repro.core import build_scenarios, explore, paper_fleet

    table = build_scenarios(paper_fleet())
    res = explore(ALL_PAPER_WORKLOADS, table)
    n_w, n_s, _ = res.total_cf.shape
    iw, isc = np.meshgrid(np.arange(n_w), np.arange(n_s), indexing="ij")
    cf_carbon = res.total_cf[iw, isc, res.carbon_opt]
    cf_energy = res.total_cf[iw, isc, res.energy_opt]
    savings = 1.0 - cf_carbon / np.maximum(cf_energy, 1e-12)
    t = time_us(lambda: res.total_cf.sum())  # trivial; explore timed below
    return [BenchRow("fig6/carbon_vs_energy_max_saving", t,
                     f"max={savings.max() * 100:.1f}%;"
                     f"mean={savings.mean() * 100:.1f}%;"
                     f"paper_claim=29.1%")]


def fig7_charging() -> list[BenchRow]:
    """ResNet CF under charging scenarios; paper: intelligent -61.2%."""
    inf = infra("act")
    ci = ci_values()
    w = by_name("resnet50").workload
    out = {}
    for name in ("night", "avg", "intel"):
        env = reference_env(mobile=name if name != "night" else "night")
        env = Environment.make(ci[name], ci["urban"], ci["core"], ci["ciso"])
        out[name] = _solve(w, inf, env)
    saving = 1 - out["intel"]["cf"][0] / out["night"]["cf"][0]
    t = time_us(lambda: evaluate(w, inf, reference_env()))
    return [BenchRow(
        "fig7/intelligent_charging", t,
        f"mobile_cf_saving={saving * 100:.1f}%;paper_claim=61.2%;"
        f"opt_night={TARGET_NAMES[out['night']['copt']]};"
        f"opt_intel={TARGET_NAMES[out['intel']['copt']]}")]


def fig8_geo() -> list[BenchRow]:
    """Urban vs rural edge DC (geographical trade-off)."""
    ci = ci_values()
    rows = []
    for wname in ("resnet50", "mobilenet-ssd"):
        w = by_name(wname).workload
        urban = _solve(w, infra("act"), Environment.make(
            ci["night"], ci["urban"], ci["core"], ci["ciso"]))
        rural = _solve(w, infra("act", rural_edge=True), Environment.make(
            ci["night"], ci["rural"], ci["core"], ci["ciso"]))
        edge_gain = 1 - rural["cf"][1] / urban["cf"][1]
        rows.append(BenchRow(
            f"fig8/{wname}", 0.0,
            f"edge_cf_gain_rural={edge_gain * 100:.1f}%;"
            f"rural_edge_feasible={bool(rural['ok'][1])};"
            f"urban_opt={TARGET_NAMES[urban['copt']]};"
            f"rural_opt={TARGET_NAMES[rural['copt']]}"))
    return rows


def fig9_dc_ci() -> list[BenchRow]:
    """Grid-mix vs carbon-free DC; impact is workload-dependent."""
    ci = ci_values()
    rows = []
    for wname, avail in (("mobilenet-ssd", (True, True, True)),
                         ("ar-demo", (True, False, True))):
        info = by_name(wname)
        w = info.workload
        dev_inf = infra("act", device=info.device)
        mix = _solve(w, dev_inf, Environment.make(
            ci["night"], ci["urban"], ci["core"], ci["ciso"]), avail)
        free = _solve(w, dev_inf, Environment.make(
            ci["night"], ci["urban"], ci["core"], ci["carbon_free"]), avail)
        delta_dc = 1 - free["cf"][2] / mix["cf"][2]
        rows.append(BenchRow(
            f"fig9/{wname}", 0.0,
            f"dc_cf_drop_when_carbon_free={delta_dc * 100:.1f}%;"
            f"mix_opt={TARGET_NAMES[mix['copt']]};"
            f"free_opt={TARGET_NAMES[free['copt']]}"))
    return rows


def fig10_variance() -> list[BenchRow]:
    """Runtime variance shifts the carbon-optimal target (Inception)."""
    w = by_name("inception").workload
    inf = infra("act")
    rows = []
    for var in VarianceScenario:
        s = _solve(w, inf, reference_env(var))
        rows.append(BenchRow(
            f"fig10/{var.name.lower()}", 0.0,
            f"carbon_opt={TARGET_NAMES[s['copt']]};"
            f"lat={s['lat'][s['copt']] * 1e3:.1f}ms"))
    return rows


def fig11_embodied() -> list[BenchRow]:
    """ACT vs LCA embodied model can flip the optimal target."""
    env = reference_env()
    rows = []
    for wname in ("mobilenet-ssd", "mobilenet"):
        w = by_name(wname).workload
        act = _solve(w, infra("act"), env)
        lca = _solve(w, infra("lca"), env)
        rows.append(BenchRow(
            f"fig11/{wname}", 0.0,
            f"act_opt={TARGET_NAMES[act['copt']]};"
            f"lca_opt={TARGET_NAMES[lca['copt']]};"
            f"flips={act['copt'] != lca['copt']}"))
    return rows


def fig12_provisioning() -> list[BenchRow]:
    """Number of rented DC servers: efficiency-CF trade-off.

    Model (paper §5.4: 'when the number of servers increases, the latency
    and operational efficiency are improved. Due to the improved latency,
    idle overhead and embodied CF overhead are also improved'): renting n
    servers splits the optimal batch B=1024 across them; each request waits
    for its server's batch to FILL, so the effective DC computation time —
    which Table 1 multiplies into the idle and embodied terms of every
    component — scales with B/n. The queueing enters as DC-side
    interference (T_comp_H multiplier), exactly the paper's latency
    mechanism.
    """
    w = by_name("squeezenet").workload
    B = 1024.0
    arrivals_per_s = 2000.0  # request arrival rate feeding the batch queue
    env0 = reference_env()
    t_h = float(w.flops / infra("act").eff_flops[2])
    configs = []
    for n_servers in (2, 4, 8, 16, 32):
        batch = B / n_servers
        fill_s = batch / arrivals_per_s  # time to fill one server's batch
        inf = infra("act").replace(
            n_batch_dc=jnp.asarray(batch, jnp.float32))
        interf = jnp.asarray([1.0, 1.0, 1.0 + fill_s / max(t_h, 1e-9)],
                             jnp.float32)
        env = Environment(ci=env0.ci, interference=interf,
                          net_slowdown=env0.net_slowdown)
        s = _solve(w, inf, env)
        configs.append((n_servers, float(s["cf"][2]), float(s["lat"][2]),
                        s["copt"]))
    cf_first = configs[0][1]
    cf_best = min(c[1] for c in configs)
    saving = 1 - cf_best / cf_first
    shift = (TARGET_NAMES[configs[0][3]], TARGET_NAMES[configs[-1][3]])
    detail = ";".join(f"n{c[0]}:dc_cf={c[1]:.2e},lat={c[2] * 1e3:.0f}ms"
                      for c in configs)
    return [BenchRow("fig12/provisioning", 0.0,
                     f"max_saving={saving * 100:.1f}%;paper_claim=24.9%;"
                     f"opt_shift={shift[0]}->{shift[1]};" + detail)]


def fig13_knobs() -> list[BenchRow]:
    """Workload-dependent parameters: game resolution + AR/VR partitioning."""
    rows = []
    inf = infra("act")
    env = reference_env()

    # (a) game resolution FHD -> HD: pixels x0.444 scales render flops and
    # the streamed frame payload.
    g = by_name("genshin-impact")
    w_fhd = g.workload
    scale = (1280 * 720) / (1920 * 1080)
    w_hd = dataclasses.replace(
        w_fhd, flops=w_fhd.flops * scale, mem_bytes=w_fhd.mem_bytes * scale,
        data_out=w_fhd.data_out * scale)
    s_fhd = _solve(w_fhd, inf, env, g.available_targets)
    s_hd = _solve(w_hd, inf, env, g.available_targets)
    cf_fhd = s_fhd["cf"][s_fhd["copt"]]
    cf_hd = s_hd["cf"][s_hd["copt"]]
    rows.append(BenchRow(
        "fig13/game_resolution", 0.0,
        f"saving={(1 - cf_hd / cf_fhd) * 100:.1f}%;paper_claim=31.1%"))

    # (b) AR/VR pipeline partitioning vs full offload (the paper's
    # unpartitioned deployment streams everything to the DC): keeping
    # perception on-device (1) shrinks the uplink payload to the stage-
    # boundary tensor (540 -> 160 KB) and (2) raises the utilization of
    # both devices — the mobile is computing instead of idling during the
    # DC stages, cutting its idle CF (paper: -55.3%).
    ar = next(a for a in ARVR_WORKLOADS if a.name == "ar-demo")
    w = ar.workload
    inf = infra("act", device="jetson")
    s_dc = _solve(w, inf, env, (False, False, True))
    cf_baseline = s_dc["cf"][2]  # full offload

    f1, f2, f3 = ar.stage_flops_frac
    # device part: perception, no network involvement, not streaming
    w_dev = dataclasses.replace(w, flops=w.flops * f1,
                                mem_bytes=w.mem_bytes * f1,
                                data_in=jnp.zeros_like(w.data_in),
                                data_out=jnp.zeros_like(w.data_out),
                                continuous=jnp.zeros_like(w.continuous),
                                fps_req=jnp.zeros_like(w.fps_req))
    # cloud part: visual+audio with the intermediate tensor as uplink
    w_cloud = dataclasses.replace(
        w, flops=w.flops * (f2 + f3), mem_bytes=w.mem_bytes * (f2 + f3),
        data_in=jnp.asarray(ar.stage_bytes[1], jnp.float32))
    s_dev = _solve(w_dev, inf, env, (True, False, False))
    s_cloud = _solve(w_cloud, inf, env, (False, False, True))
    # during the cloud stages the device is computing perception for the
    # next frame, not idling: drop the double-counted device idle from the
    # cloud part (op[D-target, Mobile-component] radio stays).
    overlap_idle = min(s_dev["cf"][0], s_cloud["op"][2][0])
    cf_part = s_dev["cf"][0] + s_cloud["cf"][2] - overlap_idle
    idle_baseline = s_dc["op"][2][0]  # device idle+radio during full offload
    idle_part = s_cloud["op"][2][0] - overlap_idle
    idle_drop = 1 - idle_part / max(idle_baseline, 1e-12)
    rows.append(BenchRow(
        "fig13/arvr_partitioning", 0.0,
        f"saving={(1 - cf_part / cf_baseline) * 100:.1f}%;paper_claim=14.8%;"
        f"idle_cf_drop={idle_drop * 100:.1f}%;paper_idle_claim=55.3%"))
    return rows


def fig14_methods() -> list[BenchRow]:
    """Scheduling methods: accuracy / overhead / CF degradation."""
    from repro.core import build_scenarios, explore, paper_fleet
    from repro.core.schedulers import (
        BOScheduler,
        ClassificationScheduler,
        EnergyAwareScheduler,
        OracleScheduler,
        RLScheduler,
        RegressionScheduler,
        build_dataset,
        evaluate_scheduler,
    )

    table = build_scenarios(paper_fleet())
    res = explore(ALL_PAPER_WORKLOADS, table)
    ds = build_dataset(ALL_PAPER_WORKLOADS, res, table)
    train, test = ds.split()
    rows = []
    for s in (OracleScheduler(), RegressionScheduler(),
              ClassificationScheduler(), BOScheduler(budget=128),
              RLScheduler(), EnergyAwareScheduler()):
        ev = evaluate_scheduler(s, train, test)
        rows.append(BenchRow(
            f"fig14/{ev.name}", ev.flops_per_decision,
            f"accuracy={ev.accuracy * 100:.1f}%;"
            f"cf_degradation={ev.cf_degradation * 100:.2f}%;"
            f"qos_violations={ev.qos_violation_rate * 100:.2f}%;"
            f"train_flops={ev.train_flops:.2e}"))
    return rows


ALL_FIGS = (fig5_design_space, fig6_scheduler_gap, fig7_charging, fig8_geo,
            fig9_dc_ci, fig10_variance, fig11_embodied, fig12_provisioning,
            fig13_knobs, fig14_methods)
