"""Beyond-paper benchmarks: GreenScale applied to the 10 LM architectures.

  * ``lm_routing``  — per-arch serving-tier decisions across a day of grid
    hours (the GreenScaleRouter on the TPU fleet): shows the carbon-optimal
    tier shifting with CI, per architecture size class.
  * ``lm_carbon_training`` — CarbonAwareTrainer ledger vs an always-on run:
    the paper's temporal/spatial/elastic levers on a training fleet.
"""

from __future__ import annotations


from benchmarks.common import BenchRow, TARGET_NAMES, time_us
from repro.configs import ARCH_IDS, get_config
from repro.core import ChargingBehavior, Grid, grid_trace, mobile_carbon_intensity
from repro.core.carbon_model import Environment
from repro.serve.router import GreenScaleRouter, Request
from repro.train.carbon_aware import CarbonAwareTrainer, CarbonSchedule, PodSpec


def lm_routing() -> list[BenchRow]:
    ciso = grid_trace(Grid.CISO)
    rural = grid_trace(Grid.RURAL)
    ci_mobile = float(mobile_carbon_intensity(ChargingBehavior.AVERAGE, ciso))
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        router = GreenScaleRouter(cfg)
        # on-device only plausible under ~8B params
        fits_device = cfg.active_param_count() < 9e9
        req = Request(prompt_tokens=512, max_new_tokens=128,
                      latency_budget_s=5.0,
                      available=(fits_device, True, True))
        picks = []
        t = None
        for hour in range(0, 24, 2):
            env = Environment.make(
                ci_mobile, float(rural.ci_hourly[hour]),
                float(ciso.ci_hourly.mean()), float(ciso.ci_hourly[hour]))
            d = router.route(req, env)
            picks.append(d.target)
            if t is None:
                import jax.numpy as jnp

                from repro.serve.router import request_workload

                t = time_us(lambda: router._route_one(
                    request_workload(cfg, req), env,
                    jnp.asarray(req.available)))
        hist = {TARGET_NAMES[i]: picks.count(i) for i in range(3)}
        rows.append(BenchRow(
            f"lm_routing/{arch}", t or 0.0,
            f"tier_picks_over_day={hist};"
            f"active_params={cfg.active_param_count() / 1e9:.1f}B"))
    return rows


def lm_carbon_training() -> list[BenchRow]:
    pods = [
        PodSpec(name="ciso-pod", trace=grid_trace(Grid.CISO), chips=256,
                embodied_g=256 * 0.9e6),
        PodSpec(name="rural-pod", trace=grid_trace(Grid.RURAL), chips=256,
                embodied_g=256 * 0.9e6),
    ]
    rows = []
    for label, sched in (
            ("greedy", CarbonSchedule()),
            ("deadline72h", CarbonSchedule(deadline_h=72)),
            ("no-elastic", CarbonSchedule(elastic=False))):
        tr = CarbonAwareTrainer(pods=pods, schedule=sched,
                                steps_per_hour_full=2000)
        ledger = tr.run(total_steps=100_000, start_hour=0)
        aware = tr.total_carbon(ledger)
        base, base_h = tr.baseline_carbon(100_000)
        hours = len(ledger)
        migrations = sum(1 for r in ledger if r.action == "migrate+train")
        pauses = sum(1 for r in ledger if r.action == "pause")
        rows.append(BenchRow(
            f"lm_carbon_training/{label}", 0.0,
            f"saving={(1 - aware / base) * 100:.1f}%;hours={hours}"
            f"(baseline {base_h});migrations={migrations};pauses={pauses};"
            f"carbon={aware / 1e3:.1f}kg_vs_{base / 1e3:.1f}kg"))
    return rows


def run() -> list[BenchRow]:
    return lm_routing() + lm_carbon_training()
