"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads the dry-run artifact JSON (produced by ``repro.launch.dryrun --json``)
and derives, per cell:

  compute term    = HLO_FLOPs / (chips x 197e12 FLOP/s)
  memory term     = HLO_bytes / (chips x 819e9 B/s)
  collective term = collective_bytes / (chips x 50e9 B/s per link)

HLO quantities from cost_analysis are *per device* (post-SPMD local module),
so per-chip terms divide by the per-chip rates directly; fleet totals are
per-device x chips. MODEL_FLOPS = 6·N·D (train, dense), 6·N_active·D (MoE),
or 2·N_active·D_new (decode); the MODEL/HLO ratio flags remat/masking waste.
"""

from __future__ import annotations

import dataclasses
import json
import os

from benchmarks.common import BenchRow
from repro.configs import get_config, get_shape
from repro.configs.base import ShapeKind
from repro.core.constants import (
    TPU_V5E_HBM_BW,
    TPU_V5E_ICI_BW,
    TPU_V5E_PEAK_BF16_FLOPS,
)

DEFAULT_ARTIFACT = os.path.join(os.path.dirname(__file__), "..",
                                "artifacts", "dryrun_baseline.json")


def model_flops(arch: str, shape_id: str) -> float:
    cfg = get_config(arch)
    shape = get_shape(shape_id)
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == ShapeKind.TRAIN:
        return 6.0 * n_active * tokens
    if shape.kind == ShapeKind.PREFILL:
        return 2.0 * n_active * tokens
    # decode: one new token per sequence
    return 2.0 * n_active * shape.global_batch


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    chips: int
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    peak_mem_gib: float

    def derived(self) -> str:
        return (f"compute={self.t_compute:.4f}s;memory={self.t_memory:.4f}s;"
                f"collective={self.t_collective:.4f}s;"
                f"bound={self.bottleneck};"
                f"useful={self.useful_ratio:.2f};"
                f"peak={self.peak_mem_gib:.1f}GiB")


def analyze(record: dict) -> RooflineRow | None:
    if not record.get("ok"):
        return None
    chips = 1
    for d in record["mesh"].split("x"):
        chips *= int(d)
    # cost_analysis numbers are per-device
    t_c = record["flops"] / TPU_V5E_PEAK_BF16_FLOPS
    t_m = record["hlo_bytes"] / TPU_V5E_HBM_BW
    coll = record["collectives"].get("total", 0.0)
    t_x = coll / TPU_V5E_ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bound = max(terms, key=terms.get)
    mf = model_flops(record["arch"], record["shape"])
    hlo_total = record["flops"] * chips
    return RooflineRow(
        arch=record["arch"], shape=record["shape"], mesh=record["mesh"],
        chips=chips, t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bound, model_flops=mf, hlo_flops_total=hlo_total,
        useful_ratio=mf / max(hlo_total, 1e-30),
        peak_mem_gib=record["peak_mem_per_device"] / 2 ** 30)


def run(artifact: str = DEFAULT_ARTIFACT) -> list[BenchRow]:
    if not os.path.exists(artifact):
        return [BenchRow("roofline/ARTIFACT_MISSING", 0.0,
                         f"run `python -m repro.launch.dryrun --all --json "
                         f"{artifact}` first")]
    with open(artifact) as f:
        records = json.load(f)
    rows = []
    for rec in records:
        rr = analyze(rec)
        if rr is None:
            rows.append(BenchRow(
                f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}", 0.0,
                "SKIP:" + rec.get("error", "")[:70]))
            continue
        rows.append(BenchRow(
            f"roofline/{rr.arch}/{rr.shape}/{rr.mesh}", 0.0, rr.derived()))
    return rows
