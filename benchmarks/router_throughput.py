"""Router throughput: batched vmap routing vs the scalar per-request loop.

The scalar path pays one jitted call + host sync per request; the batched
path routes the whole stream in one vmapped call. Scalar cost is measured
on a subsample (per-request cost is constant — same jitted function every
call) and the speedup is reported at the full request count.

Run:  PYTHONPATH=src python -m benchmarks.router_throughput [--n 10000]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import BenchRow
from repro.configs import get_config
from repro.core.carbon_model import Environment
from repro.serve import FleetRouter, GreenScaleRouter, Request, RequestBatch

ARCH = "h2o-danube-1.8b"


def synthetic_batch(n: int, seed: int = 0) -> RequestBatch:
    rng = np.random.default_rng(seed)
    prompt = rng.integers(16, 4096, n).astype(np.float64)
    new = rng.integers(8, 512, n).astype(np.float64)
    budget = rng.choice([0.5, 2.0, 10.0], n)
    # big prompts never fit on-device (the 72B-style availability mask)
    avail = np.ones((n, 3), bool)
    avail[:, 0] = prompt < 2048
    return RequestBatch(prompt_tokens=prompt, max_new_tokens=new,
                        latency_budget_s=budget,
                        bytes_per_token=np.full(n, 4.0), available=avail)


def run(n: int = 10_000, scalar_sample: int = 256) -> list[BenchRow]:
    cfg = get_config(ARCH)
    router = GreenScaleRouter(cfg)
    env = Environment.make(300.0, 350.0, 280.0, 320.0)
    batch = synthetic_batch(n)

    reqs = [Request(prompt_tokens=int(batch.prompt_tokens[i]),
                    max_new_tokens=int(batch.max_new_tokens[i]),
                    latency_budget_s=float(batch.latency_budget_s[i]),
                    available=tuple(bool(x) for x in batch.available[i]))
            for i in range(scalar_sample)]
    router.route(reqs[0], env)  # compile/warm the scalar path
    t0 = time.perf_counter()
    for r in reqs:
        router.route(r, env)
    scalar_us = (time.perf_counter() - t0) / scalar_sample * 1e6

    out = router.route_batch_arrays(batch, env)  # compile/warm
    jax.block_until_ready(out.target)
    reps = 5
    t0 = time.perf_counter()
    for _ in range(reps):
        out = router.route_batch_arrays(batch, env)
    jax.block_until_ready(out.target)
    batched_us = (time.perf_counter() - t0) / reps / n * 1e6

    speedup = scalar_us / batched_us
    rows = [
        BenchRow("router_scalar", scalar_us,
                 f"req/s={1e6 / scalar_us:.0f} (sampled n={scalar_sample})"),
        BenchRow("router_batched", batched_us,
                 f"req/s={1e6 / batched_us:.0f} (n={n})"),
        BenchRow("router_batched_speedup", batched_us,
                 f"{speedup:.0f}x over scalar loop at n={n}"),
    ]

    fleet = FleetRouter(cfg)
    rng = np.random.default_rng(1)
    region = rng.integers(0, len(fleet.regions), n)
    t_hours = rng.uniform(0.0, 24.0, n)
    res = fleet.route_stream(batch, region, t_hours)  # compile/warm
    jax.block_until_ready(res.target)
    t0 = time.perf_counter()
    for _ in range(reps):
        res = fleet.route_stream(batch, region, t_hours)
    jax.block_until_ready(res.target)
    fleet_us = (time.perf_counter() - t0) / reps / n * 1e6
    rows.append(BenchRow(
        "fleet_router", fleet_us,
        f"req/s={1e6 / fleet_us:.0f} regions={len(fleet.regions)} "
        f"saved_vs_latency_g={float(res.saved_vs_latency_g):.3g}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--scalar-sample", type=int, default=256)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(args.n, args.scalar_sample):
        print(row.csv())


if __name__ == "__main__":
    main()
