"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run``.

One benchmark per paper table/figure + the beyond-paper LM suites.
Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark group names")
    ap.add_argument("--artifact", default=None,
                    help="dry-run JSON for the roofline table")
    ap.add_argument("--smoke", action="store_true",
                    help="assert-runs-only mode: tiny streams, one rep — "
                         "keeps every registration importable/runnable in "
                         "CI without benchmarking anything meaningful")
    args = ap.parse_args()

    from benchmarks import (
        lm_design_space,
        policy_throughput,
        roofline,
        router_throughput,
        scenario_matrix,
    )
    from benchmarks.paper_figures import ALL_FIGS

    groups = [(fig.__name__, fig) for fig in ALL_FIGS]
    groups.append(("lm_design_space", lm_design_space.run))
    if args.smoke:
        groups.append(("router_throughput",
                       lambda: router_throughput.run(n=512,
                                                     scalar_sample=8)))
        groups.append(("policy_throughput",
                       lambda: policy_throughput.run(n=2_000, reps=1)))
        # tiny streams: the matrix rows are not meaningful timings in
        # smoke, but every gate row still ASSERTS (CI greps them)
        groups.append(("scenario_matrix",
                       lambda: scenario_matrix.run(
                           n=400, csv_path="scenario-matrix.csv")))
    else:
        groups.append(("router_throughput", router_throughput.run))
        # smaller stream than the standalone default keeps the full driver
        # quick; `python -m benchmarks.policy_throughput` has the 1M numbers
        groups.append(("policy_throughput",
                       lambda: policy_throughput.run(n=200_000)))
        groups.append(("scenario_matrix",
                       lambda: scenario_matrix.run(
                           csv_path="scenario-matrix.csv")))
    if args.artifact:
        groups.append(("roofline", lambda: roofline.run(args.artifact)))
    else:
        groups.append(("roofline", roofline.run))

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in groups:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            for row in fn():
                print(row.csv(), flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0.0,ERROR", flush=True)
            traceback.print_exc()
        print(f"# {name} took {time.time() - t0:.1f}s", file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
